//! Integration: the `blink::adaptive` observe → refit → re-plan → act
//! loop — the acceptance story of the adaptive subsystem.
//!
//! * recursive least squares is a **bit-exact fixed point** under
//!   self-observation: a model reproducing a `SizeLaw::power` curve fed
//!   its own predictions never moves θ, by the zero-residual early-out
//!   rather than numerical luck;
//! * the adaptive outcome **fingerprint is order-deterministic**: the
//!   whole loop replays byte-identically under every worker count of the
//!   `util::par` thread matrix (the violating seed is printed);
//! * the `testkit::check_adaptive` **differential invariants** hold on
//!   smoke batches: realized adaptive cost dominates the static pick's,
//!   the well-estimated `linear` preset never re-plans, and the
//!   systematically under-fit `superlinear` preset always re-plans
//!   somewhere in the batch;
//! * the controller's **surplus arm** works end to end: a hand-planted
//!   3× over-prediction makes the refit diverge downward, the re-plan
//!   wants fewer machines, and the loop retires the excess — cost-gated,
//!   never emptying the fleet, never firing both arms at once.

use std::collections::BTreeMap;

use blink::blink::models::{ModelKind, SelectedModel};
use blink::blink::{
    adapt, AdaptConfig, Advisor, ExecMemoryPredictor, RlsState, RustFit, SizePredictor,
    TrainedProfile,
};
use blink::cost::pricing_by_name;
use blink::sim::{scenario, InstanceCatalog, InstanceType};
use blink::testkit::{check_adaptive, Violation};
use blink::util::par::sweep_range_with;
use blink::workloads::{AppModel, DagSpec, SizeLaw, SizeNoise, SynthConfig, FULL_SCALE};

fn render(violations: &[Violation]) -> String {
    violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
}

#[test]
fn rls_self_observation_of_a_fitted_power_law_is_a_bit_exact_fixed_point() {
    // a quadratic model reproducing SizeLaw::power(3, 1.4, 2): the refit
    // fed its own predictions must never move θ, bit for bit
    let law = SizeLaw::power(3.0, 1.4, 2.0);
    let model = SelectedModel {
        kind: ModelKind::Quadratic,
        theta: vec![3.0, 0.0, 1.4],
        cv_rmse: 0.0,
        cv_rel_err: 0.0,
    };
    let mut state = RlsState::from_model(&model, 1e6);
    for s in 1..=10 {
        let s = s as f64;
        let (got, want) = (state.predict(s), law.at(s));
        assert!((got - want).abs() <= 1e-9 * want.abs(), "scale {s}: {got} vs {want}");
    }
    let before: Vec<u64> = state.theta.iter().map(|t| t.to_bits()).collect();
    for i in 0..500usize {
        let s = 1.0 + (i % 37) as f64 * 8.25;
        let echo = state.predict(s);
        state.observe(s, echo);
    }
    assert_eq!(state.updates, 500, "every self-observation still counts as an update");
    let after: Vec<u64> = state.theta.iter().map(|t| t.to_bits()).collect();
    assert_eq!(before, after, "self-observation drifted θ");
    // and the undisturbed state still tracks the generating law
    let (got, want) = (state.predict(123.0), law.at(123.0));
    assert!((got - want).abs() <= 1e-6 * want, "{got} vs {want}");
}

#[test]
fn adaptive_outcomes_replay_bit_identically_across_the_thread_matrix() {
    // the loop's answer is a pure function of (profile, seed): re-running
    // the same batch under every worker count must reproduce the serial
    // fingerprints byte for byte, however the threads interleave
    let catalog = InstanceCatalog::by_name("paper").unwrap();
    let pricing = pricing_by_name("machine-seconds").unwrap();
    for preset in ["noisy", "superlinear"] {
        let cfg = SynthConfig::by_name(preset).unwrap();
        let mut backend = RustFit::default();
        let mut advisor = Advisor::builder().max_machines(12).build(&mut backend);
        let runs: Vec<(u64, TrainedProfile)> = cfg
            .generate_many(5, 3)
            .into_iter()
            .map(|(seed, app)| (seed, advisor.profile(&app)))
            .collect();
        let fingerprint = |seed: u64, profile: &TrainedProfile| {
            adapt(
                profile,
                300.0,
                &catalog,
                pricing.as_ref(),
                &scenario::NoDisturbances,
                &AdaptConfig { seed, ..Default::default() },
            )
            .unwrap()
            .fingerprint()
        };
        let reference: Vec<String> = runs.iter().map(|(s, p)| fingerprint(*s, p)).collect();
        for workers in [0usize, 1, 2, 3, 8, 64, 200] {
            let got = sweep_range_with(workers, 0, runs.len() - 1, |i| {
                let (seed, profile) = &runs[i];
                fingerprint(*seed, profile)
            });
            for (i, fp) in got.iter().enumerate() {
                assert_eq!(
                    fp, &reference[i],
                    "preset {preset} seed {}: {workers}-worker fingerprint diverged from serial",
                    runs[i].0
                );
            }
        }
    }
}

#[test]
fn check_adaptive_smoke_linear_never_replans() {
    let (checks, violations) = check_adaptive("linear", 1, 3);
    assert!(checks >= 6, "{checks}");
    assert!(violations.is_empty(), "{}", render(&violations));
}

#[test]
fn check_adaptive_smoke_superlinear_replans_and_dominates() {
    let (checks, violations) = check_adaptive("superlinear", 1, 3);
    assert!(checks >= 6, "{checks}");
    assert!(violations.is_empty(), "{}", render(&violations));
}

/// A trained profile whose planted size model predicts 3× the true flat
/// 6 GB footprint: the static plan over-provisions, the run's own
/// observations pull the refit back down, and the controller's surplus
/// arm must retire the excess machines.
fn shrinkable_profile() -> TrainedProfile {
    let app = AppModel {
        name: "shrinkable".into(),
        input_mb_full: 4000.0,
        blocks_full: 4,
        cached_laws: vec![SizeLaw::new(6000.0, 0.0)],
        exec_law: SizeLaw::new(500.0, 0.0),
        size_noise: SizeNoise::new(0.0, 1.0),
        iterations: 5,
        compute_s_per_mb: 0.01,
        cached_speedup: 97.0,
        recompute_factor: 1.0,
        serial_fixed_s: 1.0,
        serial_per_scale_s: 0.0,
        shuffle_mb_full: 0.0,
        task_overhead_s: 0.01,
        task_time_sigma: 0.0,
        per_partition_overhead_mb: 0.0,
        parallelism_cap: None,
        force_block_s: false,
        enlarged_scale: FULL_SCALE,
        dag_spec: DagSpec::Layered { depth: 1, width: 1, cached: 1, iterations: 5 },
    };
    let planted = SelectedModel {
        kind: ModelKind::Linear,
        theta: vec![18_000.0, 0.0],
        cv_rmse: 0.0,
        cv_rel_err: 0.0,
    };
    let exec = SelectedModel {
        kind: ModelKind::Linear,
        theta: vec![500.0, 0.0],
        cv_rmse: 0.0,
        cv_rel_err: 0.0,
    };
    let mut models = BTreeMap::new();
    models.insert(0usize, planted);
    TrainedProfile {
        app,
        scales: vec![],
        max_machines: 12,
        sample_cost_machine_s: 0.0,
        runs: vec![],
        models: Some((SizePredictor { models }, ExecMemoryPredictor { model: exec })),
    }
}

#[test]
fn over_predicted_footprint_scales_in_and_the_cheaper_run_is_adopted() {
    // the scale-in regression: before the surplus arm existed, this
    // decision would have been advisory-only (add = 0) and the loop would
    // have kept billing every over-provisioned machine to the end
    let trained = shrinkable_profile();
    let catalog = InstanceCatalog::single(InstanceType::paper_worker());
    let pricing = pricing_by_name("machine-seconds").unwrap();
    let o = adapt(
        &trained,
        FULL_SCALE,
        &catalog,
        pricing.as_ref(),
        &scenario::NoDisturbances,
        &AdaptConfig::default(),
    )
    .unwrap();
    assert!((o.predicted_mb - 18_000.0).abs() < 1e-9, "{}", o.predicted_mb);
    assert!(o.machines >= 2, "18 GB predicted cannot fit one worker: {}", o.machines);
    let d = o.decision.as_ref().expect("a 3x over-prediction must trip the 0.5 threshold");
    assert!(d.refit_mb < 7000.0, "refit must track the observed ~6000 MB: {}", d.refit_mb);
    assert!(d.divergence >= 0.5, "{}", d.divergence);
    assert!(d.deficit_mb < 0.0, "the observed footprint fits the fleet: {}", d.deficit_mb);
    assert_eq!(d.add_machines, 0, "a surplus must never scale out");
    assert!(
        d.replanned_machines < o.machines,
        "re-plan of a 6 GB footprint wants fewer than {} machines, got {}",
        o.machines,
        d.replanned_machines
    );
    assert_eq!(d.remove_machines, o.machines - d.replanned_machines.max(1));
    assert!(d.remove_machines >= 1);
    // retiring idle machines mid-run is strictly cheaper, so the cost
    // gate adopts the corrective run
    assert!(o.adopted, "scale-in must pay for itself");
    assert!(o.adaptive_cost < o.static_cost, "{} vs {}", o.adaptive_cost, o.static_cost);
    assert!(o.adaptive_time_s <= o.static_time_s + 1e-9);
    assert!(o.fingerprint().contains("replan@"));
}

#[test]
fn linear_preset_never_arms_the_controller() {
    // the well-estimated preset must not trigger either controller arm:
    // no decision, and the adaptive answer is the static one bit for bit
    let catalog = InstanceCatalog::by_name("paper").unwrap();
    let pricing = pricing_by_name("machine-seconds").unwrap();
    let cfg = SynthConfig::by_name("linear").unwrap();
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().max_machines(12).build(&mut backend);
    for (seed, app) in cfg.generate_many(1, 3) {
        let profile = advisor.profile(&app);
        let o = adapt(
            &profile,
            300.0,
            &catalog,
            pricing.as_ref(),
            &scenario::NoDisturbances,
            &AdaptConfig { seed, ..Default::default() },
        )
        .unwrap();
        assert!(o.decision.is_none(), "seed {seed}: {:?}", o.decision);
        assert!(!o.adopted, "seed {seed}");
        assert_eq!(o.adaptive_time_s.to_bits(), o.static_time_s.to_bits(), "seed {seed}");
        assert_eq!(o.adaptive_cost.to_bits(), o.static_cost.to_bits(), "seed {seed}");
    }
}

#[test]
fn sublinear_preset_decisions_respect_the_controller_arm_invariants() {
    // a zero threshold makes the divergence check fire at the first
    // eligible barrier for every workload, whatever the fit quality —
    // exercising both controller arms' bookkeeping across a batch
    let catalog = InstanceCatalog::by_name("paper").unwrap();
    let pricing = pricing_by_name("machine-seconds").unwrap();
    let cfg = SynthConfig::by_name("sublinear").unwrap();
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().max_machines(12).build(&mut backend);
    let mut decisions = 0usize;
    for (seed, app) in cfg.generate_many(1, 3) {
        let profile = advisor.profile(&app);
        let o = adapt(
            &profile,
            300.0,
            &catalog,
            pricing.as_ref(),
            &scenario::NoDisturbances,
            &AdaptConfig { seed, threshold: 0.0, ..Default::default() },
        )
        .unwrap();
        assert!(
            o.adaptive_cost <= o.static_cost * (1.0 + 1e-9),
            "seed {seed}: {} vs {}",
            o.adaptive_cost,
            o.static_cost
        );
        let Some(d) = &o.decision else { continue };
        decisions += 1;
        assert!(
            d.add_machines == 0 || d.remove_machines == 0,
            "seed {seed}: both controller arms fired"
        );
        if d.add_machines > 0 {
            assert!(d.deficit_mb > 0.0, "seed {seed}: scale-out without a deficit");
        }
        if d.remove_machines > 0 {
            assert!(d.deficit_mb <= 0.0, "seed {seed}: scale-in without a surplus");
            assert!(d.replanned_machines < o.machines, "seed {seed}");
            assert!(d.remove_machines < o.machines, "seed {seed}: fleet must survive");
        }
    }
    assert!(decisions >= 1, "a zero threshold must fire on every modeled workload");
}

#[test]
#[ignore = "release-matrix scale; CI runs it with --include-ignored"]
fn check_adaptive_release_matrix() {
    for preset in ["linear", "noisy", "superlinear"] {
        let (checks, violations) = check_adaptive(preset, 1, 8);
        assert!(checks >= 16, "{preset}: {checks}");
        assert!(violations.is_empty(), "{preset}:\n{}", render(&violations));
    }
}
