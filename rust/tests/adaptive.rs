//! Integration: the `blink::adaptive` observe → refit → re-plan → act
//! loop — the acceptance story of the adaptive subsystem.
//!
//! * recursive least squares is a **bit-exact fixed point** under
//!   self-observation: a model reproducing a `SizeLaw::power` curve fed
//!   its own predictions never moves θ, by the zero-residual early-out
//!   rather than numerical luck;
//! * the adaptive outcome **fingerprint is order-deterministic**: the
//!   whole loop replays byte-identically under every worker count of the
//!   `util::par` thread matrix (the violating seed is printed);
//! * the `testkit::check_adaptive` **differential invariants** hold on
//!   smoke batches: realized adaptive cost dominates the static pick's,
//!   the well-estimated `linear` preset never re-plans, and the
//!   systematically under-fit `superlinear` preset always re-plans
//!   somewhere in the batch.

use blink::blink::models::{ModelKind, SelectedModel};
use blink::blink::{adapt, AdaptConfig, Advisor, RlsState, RustFit, TrainedProfile};
use blink::cost::pricing_by_name;
use blink::sim::{scenario, InstanceCatalog};
use blink::testkit::{check_adaptive, Violation};
use blink::util::par::sweep_range_with;
use blink::workloads::{SizeLaw, SynthConfig};

fn render(violations: &[Violation]) -> String {
    violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
}

#[test]
fn rls_self_observation_of_a_fitted_power_law_is_a_bit_exact_fixed_point() {
    // a quadratic model reproducing SizeLaw::power(3, 1.4, 2): the refit
    // fed its own predictions must never move θ, bit for bit
    let law = SizeLaw::power(3.0, 1.4, 2.0);
    let model = SelectedModel {
        kind: ModelKind::Quadratic,
        theta: vec![3.0, 0.0, 1.4],
        cv_rmse: 0.0,
        cv_rel_err: 0.0,
    };
    let mut state = RlsState::from_model(&model, 1e6);
    for s in 1..=10 {
        let s = s as f64;
        let (got, want) = (state.predict(s), law.at(s));
        assert!((got - want).abs() <= 1e-9 * want.abs(), "scale {s}: {got} vs {want}");
    }
    let before: Vec<u64> = state.theta.iter().map(|t| t.to_bits()).collect();
    for i in 0..500usize {
        let s = 1.0 + (i % 37) as f64 * 8.25;
        let echo = state.predict(s);
        state.observe(s, echo);
    }
    assert_eq!(state.updates, 500, "every self-observation still counts as an update");
    let after: Vec<u64> = state.theta.iter().map(|t| t.to_bits()).collect();
    assert_eq!(before, after, "self-observation drifted θ");
    // and the undisturbed state still tracks the generating law
    let (got, want) = (state.predict(123.0), law.at(123.0));
    assert!((got - want).abs() <= 1e-6 * want, "{got} vs {want}");
}

#[test]
fn adaptive_outcomes_replay_bit_identically_across_the_thread_matrix() {
    // the loop's answer is a pure function of (profile, seed): re-running
    // the same batch under every worker count must reproduce the serial
    // fingerprints byte for byte, however the threads interleave
    let catalog = InstanceCatalog::by_name("paper").unwrap();
    let pricing = pricing_by_name("machine-seconds").unwrap();
    for preset in ["noisy", "superlinear"] {
        let cfg = SynthConfig::by_name(preset).unwrap();
        let mut backend = RustFit::default();
        let mut advisor = Advisor::builder().max_machines(12).build(&mut backend);
        let runs: Vec<(u64, TrainedProfile)> = cfg
            .generate_many(5, 3)
            .into_iter()
            .map(|(seed, app)| (seed, advisor.profile(&app)))
            .collect();
        let fingerprint = |seed: u64, profile: &TrainedProfile| {
            adapt(
                profile,
                300.0,
                &catalog,
                pricing.as_ref(),
                &scenario::NoDisturbances,
                &AdaptConfig { seed, ..Default::default() },
            )
            .unwrap()
            .fingerprint()
        };
        let reference: Vec<String> = runs.iter().map(|(s, p)| fingerprint(*s, p)).collect();
        for workers in [0usize, 1, 2, 3, 8, 64, 200] {
            let got = sweep_range_with(workers, 0, runs.len() - 1, |i| {
                let (seed, profile) = &runs[i];
                fingerprint(*seed, profile)
            });
            for (i, fp) in got.iter().enumerate() {
                assert_eq!(
                    fp, &reference[i],
                    "preset {preset} seed {}: {workers}-worker fingerprint diverged from serial",
                    runs[i].0
                );
            }
        }
    }
}

#[test]
fn check_adaptive_smoke_linear_never_replans() {
    let (checks, violations) = check_adaptive("linear", 1, 3);
    assert!(checks >= 6, "{checks}");
    assert!(violations.is_empty(), "{}", render(&violations));
}

#[test]
fn check_adaptive_smoke_superlinear_replans_and_dominates() {
    let (checks, violations) = check_adaptive("superlinear", 1, 3);
    assert!(checks >= 6, "{checks}");
    assert!(violations.is_empty(), "{}", render(&violations));
}

#[test]
#[ignore = "release-matrix scale; CI runs it with --include-ignored"]
fn check_adaptive_release_matrix() {
    for preset in ["linear", "noisy", "superlinear"] {
        let (checks, violations) = check_adaptive(preset, 1, 8);
        assert!(checks >= 16, "{preset}: {checks}");
        assert!(violations.is_empty(), "{preset}:\n{}", render(&violations));
    }
}
