//! Randomized cross-module invariants (the proptest-style sweep over the
//! coordinator state machines the guides call for).

use blink::blink::{select_cluster_size, RustFit};
use blink::blink::models::{select_model, FitBackend, FitProblem};
use blink::memory::EvictionPolicy;
use blink::metrics::{Event, EventLog, RunSummary};
use blink::sim::{simulate, CachedData, ClusterSpec, MachineSpec, SimOptions, WorkloadProfile};
use blink::util::prng::Rng;
use blink::util::prop::{check, Config};
use blink::util::json;

fn random_profile(rng: &mut Rng, size: usize) -> WorkloadProfile {
    let parallelism = 4 + rng.below(size.max(1) * 4 + 4);
    WorkloadProfile {
        name: "prop".into(),
        scale: rng.range(1.0, 2000.0),
        input_mb: rng.range(10.0, 20_000.0),
        parallelism,
        cached: (0..1 + rng.below(2))
            .map(|i| {
                let mb = rng.range(1.0, 30_000.0);
                CachedData { id: i, true_total_mb: mb, measured_total_mb: mb }
            })
            .collect(),
        iterations: rng.below(6),
        compute_s_per_mb: rng.range(0.001, 0.3),
        cached_speedup: 97.0,
        recompute_factor: rng.range(0.2, 8.0),
        serial_s: rng.range(0.0, 5.0),
        shuffle_mb: rng.range(0.0, 500.0),
        exec_mem_total_mb: rng.range(0.0, 20_000.0),
        task_overhead_s: 0.01,
        task_time_sigma: rng.range(0.0, 0.5),
        sample_prep_s: rng.range(0.0, 10.0),
    }
}

#[test]
fn sim_invariants_hold_for_arbitrary_profiles() {
    check(
        &Config { cases: 48, seed: 0xabcd, max_size: 12 },
        |rng, size| {
            let machines = 1 + rng.below(8);
            (random_profile(rng, size), machines, rng.next_u64())
        },
        |(profile, machines, seed)| {
            let res = simulate(
                profile,
                &ClusterSpec::workers(*machines),
                SimOptions {
                    policy: EvictionPolicy::Lru,
                    seed: *seed,
                    compute: None,
                    detailed_log: true,
                },
            )
            .map_err(|e| e.to_string())?;
            let s = RunSummary::from_log(&res.log);
            // time moves forward, cost = n x time
            if s.duration_s < profile.sample_prep_s - 1e-9 {
                return Err("clock went backwards".into());
            }
            if (s.cost_machine_s - s.duration_s * *machines as f64).abs() > 1e-6 {
                return Err("cost != machines x time".into());
            }
            // every iteration job issues exactly `parallelism` tasks
            let expected = profile.parallelism * (profile.iterations + 1);
            if s.tasks != expected {
                return Err(format!("tasks {} != {expected}", s.tasks));
            }
            // iteration tasks distribute over machines completely
            let iter_total: usize = res.iter_tasks_per_machine.iter().sum();
            if iter_total != profile.parallelism * profile.iterations {
                return Err("iteration tasks lost".into());
            }
            // cached fraction is a fraction
            if !(0.0..=1.0 + 1e-9).contains(&res.cached_fraction_after_load) {
                return Err("cached fraction out of range".into());
            }
            // measured cached size never exceeds what the app reports
            if s.total_cached_mb() > profile.total_cached_measured_mb() + 1e-6 {
                return Err("cached more than the dataset".into());
            }
            // log roundtrip is lossless
            let back = EventLog::from_jsonl(&res.log.to_jsonl()).map_err(|e| e.to_string())?;
            if RunSummary::from_log(&back) != s {
                return Err("jsonl roundtrip changed the summary".into());
            }
            Ok(())
        },
    );
}

#[test]
fn more_machines_never_increase_duration_much_when_cached() {
    // monotonicity modulo coordination overhead: with zero noise and a
    // fully-cached dataset, doubling machines never doubles the time
    check(
        &Config { cases: 24, seed: 0x1234, max_size: 8 },
        |rng, size| {
            let mut p = random_profile(rng, size);
            p.task_time_sigma = 0.0;
            p.cached = vec![CachedData { id: 0, true_total_mb: 100.0, measured_total_mb: 100.0 }];
            p.exec_mem_total_mb = 0.0;
            (p, rng.next_u64())
        },
        |(p, seed)| {
            let t = |n| {
                let res = simulate(
                    p,
                    &ClusterSpec::workers(n),
                    SimOptions {
                        policy: EvictionPolicy::Lru,
                        seed: *seed,
                        compute: None,
                        detailed_log: false,
                    },
                )
                .expect("worker cluster is valid");
                RunSummary::from_log(&res.log).duration_s
            };
            let (t2, t4) = (t(2), t(4));
            if t4 > t2 * 2.0 + 1.0 {
                return Err(format!("t4={t4} explodes vs t2={t2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn selector_is_scale_monotone() {
    // more cached data never selects fewer machines
    check(
        &Config { cases: 64, seed: 0x51, max_size: 32 },
        |rng, _| {
            let c1 = rng.range(10.0, 80_000.0);
            let c2 = c1 + rng.range(0.0, 40_000.0);
            let e = rng.range(0.0, 30_000.0);
            (c1, c2, e)
        },
        |&(c1, c2, e)| {
            let m = MachineSpec::worker_node();
            let n1 = select_cluster_size(c1, e, &m, 64).machines;
            let n2 = select_cluster_size(c2, e, &m, 64).machines;
            if n2 < n1 {
                return Err(format!("{c1}->{n1} but {c2}->{n2}"));
            }
            Ok(())
        },
    );
}

#[test]
fn model_selection_interpolates_training_points() {
    // whatever family wins, it must fit the (noiseless) training data
    check(
        &Config { cases: 48, seed: 0x77, max_size: 10 },
        |rng, size| {
            let n = 3 + rng.below(size.max(1).min(8));
            let th0 = rng.range(0.0, 20.0);
            let th1 = rng.range(0.01, 50.0);
            let pts: Vec<(f64, f64)> =
                (1..=n).map(|s| (s as f64, th0 + th1 * s as f64)).collect();
            pts
        },
        |pts| {
            let m = select_model(&mut RustFit::default(), pts);
            for (s, y) in pts {
                let p = m.predict(*s);
                if (p - y).abs() > 0.02 * y.abs().max(1.0) {
                    return Err(format!("{:?} misfits ({s}, {y}) -> {p}", m.kind));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fit_backend_rmse_consistent_with_theta() {
    check(
        &Config { cases: 48, seed: 0x99, max_size: 8 },
        |rng, size| {
            let n = 2 + rng.below(size.max(1).min(10));
            let x: Vec<Vec<f64>> = (0..n).map(|i| vec![1.0, (i + 1) as f64]).collect();
            let y: Vec<f64> = (0..n).map(|_| rng.range(0.0, 50.0)).collect();
            FitProblem { x, y, w: vec![1.0; n] }
        },
        |p| {
            let r = &RustFit::default().fit_batch(std::slice::from_ref(p))[0];
            let manual = blink::linalg::residual_rmse(&p.x, &p.y, &p.w, &r.theta);
            if (r.rmse - manual).abs() > 1e-9 {
                return Err(format!("rmse {} vs {manual}", r.rmse));
            }
            Ok(())
        },
    );
}

/// A random JSON value of bounded size/depth (finite numbers only: the
/// printer encodes NaN/Inf as `null` by design, which would change type).
fn random_json(rng: &mut Rng, depth: usize, size: usize) -> json::Json {
    let leaf = depth == 0 || rng.below(3) == 0;
    if leaf {
        match rng.below(4) {
            0 => json::Json::Null,
            1 => json::Json::Bool(rng.below(2) == 0),
            2 => json::Json::Num((rng.range(-1e6, 1e6) * 100.0).round() / 100.0),
            _ => {
                let n = rng.below(8);
                let s: String = (0..n)
                    .map(|_| {
                        // printable ASCII plus the escapes the writer handles
                        let pool = b"abXYZ09 \"\\\n\t/\x07";
                        pool[rng.below(pool.len())] as char
                    })
                    .collect();
                json::Json::Str(s)
            }
        }
    } else if rng.below(2) == 0 {
        let n = rng.below(size.max(1) + 1);
        json::Json::Arr((0..n).map(|_| random_json(rng, depth - 1, size / 2)).collect())
    } else {
        let n = rng.below(size.max(1) + 1);
        json::Json::Obj(
            (0..n)
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1, size / 2)))
                .collect(),
        )
    }
}

#[test]
fn json_printer_output_always_reparses_to_the_same_value() {
    check(
        &Config { cases: 128, seed: 0x5050, max_size: 10 },
        |rng, size| random_json(rng, 4, size),
        |v| {
            for text in [v.to_string(), v.pretty()] {
                let back = json::parse(&text).map_err(|e| format!("{e} in {text:?}"))?;
                if back != *v {
                    return Err(format!("{v:?} reparsed as {back:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn json_parser_survives_adversarial_mutations() {
    // mutate valid documents — truncate, splice bytes, corrupt escapes —
    // and require a clean Ok/Err from the parser every time (a panic or
    // abort fails the test process itself)
    check(
        &Config { cases: 192, seed: 0xfade, max_size: 10 },
        |rng, size| {
            let mut bytes = random_json(rng, 3, size).to_string().into_bytes();
            match rng.below(3) {
                0 => {
                    let keep = rng.below(bytes.len().max(1));
                    bytes.truncate(keep);
                }
                1 => {
                    if !bytes.is_empty() {
                        let i = rng.below(bytes.len());
                        let pool = b"[{}]\",:\\x9";
                        bytes[i] = pool[rng.below(pool.len())];
                    }
                }
                _ => {
                    let garbage = b"{\"\\u12";
                    bytes.extend_from_slice(&garbage[..rng.below(garbage.len() + 1)]);
                }
            }
            bytes
        },
        |bytes| {
            if let Ok(text) = std::str::from_utf8(bytes) {
                let _ = json::parse(text); // must return, never panic
            }
            Ok(())
        },
    );
}

#[test]
fn event_json_roundtrips_for_all_variants() {
    let events = vec![
        Event::AppStart { app: "x".into(), machines: 3, data_scale: 1.5 },
        Event::TaskEnd { stage: 1, task: 2, machine: 0, duration_s: 0.25, cached_read: true },
        Event::BlockUpdate { dataset: 0, partition: 9, size_mb: 12.5, stored: false },
        Event::Eviction { machine: 2 },
        Event::JobEnd { job: 4, duration_s: 9.0 },
        Event::ExecMemory { machine: 1, peak_mb: 333.25 },
        Event::MachineLost { machine: 2, time_s: 12.25, cached_mb_lost: 640.5, inflight_tasks: 3 },
        Event::MachineJoined { machine: 4, time_s: 15.75 },
        Event::AppEnd { duration_s: 77.5 },
    ];
    for e in events {
        let j = e.to_json().to_string();
        let parsed = json::parse(&j).unwrap();
        assert_eq!(Event::from_json(&parsed), Ok(e));
    }
}
