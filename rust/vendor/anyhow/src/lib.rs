//! Offline stand-in for the `anyhow` crate.
//!
//! The build image has no registry access, so this path dependency provides
//! exactly the API surface the `blink` crate uses — `Error`, `Result`,
//! `anyhow!`, `bail!` and `Context` — with the same semantics for message
//! construction, context chaining and `{e:#}` formatting. Replacing it with
//! the real `anyhow = "1"` is a one-line `Cargo.toml` change; no source in
//! the main crate references anything beyond this surface.

use std::fmt;

/// A message-carrying error. Like `anyhow::Error` it deliberately does NOT
/// implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Prepend a higher-level context message (the `Context` entry point).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` prints the whole chain in real anyhow; the shim keeps the
        // chain flattened into one message, so both render identically.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to results.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms_and_display() {
        let name = "linfit";
        let e = anyhow!("unknown artifact '{name}'");
        assert_eq!(e.to_string(), "unknown artifact 'linfit'");
        let e = anyhow!("{} of {}", 1, 2);
        assert_eq!(format!("{e:#}"), "1 of 2");
        let e = anyhow!(io_err());
        assert_eq!(e.to_string(), "gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "manifest.json")).unwrap_err();
        assert_eq!(e.to_string(), "reading manifest.json: gone");
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        assert_eq!(r.context("load").unwrap_err().to_string(), "load: gone");
    }

    #[test]
    fn bail_returns_error() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 7);
            }
            Ok(1)
        }
        assert_eq!(inner(false).unwrap(), 1);
        assert_eq!(inner(true).unwrap_err().to_string(), "boom 7");
    }
}
