//! Spot vs on-demand: what preemption really costs.
//!
//! ```bash
//! cargo run --release --example spot_vs_ondemand
//! ```
//!
//! Plans the same workload (svm at 40 % scale) on the cloud catalog's
//! `gp.xlarge` shape twice — priced on-demand (per-second) and priced
//! spot — then *realizes* the spot fleet under the preemption scenario
//! with the event-driven engine. The naive `SpotDiscount` quote assumes
//! the discounted machines run undisturbed; the engine run shows the
//! reclaim dropping cached partitions, the survivors paying the Area-A
//! recompute penalty, and the realized per-machine-uptime cost landing
//! above the quote — the gap the planner's risk cross-validation
//! (`blink advise --scenario spot`, i.e. `TrainedProfile::validate` in
//! the session API) is built to expose.

use blink::cost::{PerInstanceHour, PricingModel, SpotDiscount};
use blink::memory::EvictionPolicy;
use blink::metrics::{Event, RunSummary};
use blink::sim::{engine, scenario, FleetSpec, InstanceCatalog, SimOptions};
use blink::util::units::{fmt_mb, fmt_secs};
use blink::workloads::app_by_name;

fn main() {
    let app = app_by_name("svm").unwrap();
    let scale = 400.0; // 40 % of the svm input
    let profile = app.profile(scale);
    let catalog = InstanceCatalog::cloud();
    let instance = catalog.get("gp.xlarge").unwrap().clone();
    // the minimal eviction-free count for this shape: cheap, but no slack
    let machines = 3;
    let fleet = FleetSpec::homogeneous(instance.clone(), machines).unwrap();
    let opts = |seed: u64| SimOptions {
        policy: EvictionPolicy::Lru,
        seed,
        compute: None,
        detailed_log: false,
    };

    println!(
        "svm @ scale {scale:.0} ({} input) on {machines} x {} (${}/h each)\n",
        fmt_mb(app.input_mb(scale)),
        instance.name,
        instance.price_per_hour
    );

    // ---- the quotes: both assume an undisturbed run ---------------------
    let on_demand = PerInstanceHour::per_second();
    let spot = SpotDiscount::typical();
    let base = engine::run(&profile, &fleet, &scenario::NoDisturbances, opts(1)).unwrap();
    let bs = RunSummary::from_log(&base.sim.log);
    let quote_od = on_demand.price(&instance, machines, bs.duration_s);
    let quote_spot = spot.price(&instance, machines, bs.duration_s);
    println!("undisturbed run: {} ({} evictions)", fmt_secs(bs.duration_s), bs.evictions);
    println!("  on-demand quote: ${quote_od:.4}");
    println!(
        "  spot quote:      ${quote_spot:.4}  ({:.0} % off — if nothing is reclaimed)",
        spot.discount * 100.0
    );

    // ---- the realized spot run ------------------------------------------
    let disturbed = engine::run(
        &profile,
        &fleet,
        &scenario::SpotPreemption { victims: 1, ..Default::default() },
        opts(1),
    )
    .unwrap();
    let ds = RunSummary::from_log(&disturbed.sim.log);
    let lost_mb: f64 = disturbed
        .sim
        .log
        .events
        .iter()
        .filter_map(|e| match e {
            Event::MachineLost { cached_mb_lost, .. } => Some(*cached_mb_lost),
            _ => None,
        })
        .sum();
    println!("\nspot run under preemption:");
    println!(
        "  {} ({:+.0} % vs undisturbed), {} machine(s) reclaimed, {} of cache lost",
        fmt_secs(ds.duration_s),
        (ds.duration_s / bs.duration_s - 1.0) * 100.0,
        ds.machines_lost,
        fmt_mb(lost_mb),
    );
    let realized_spot = spot.price_timeline(&disturbed.timeline);
    println!(
        "  realized spot cost (per-machine uptime): ${realized_spot:.4}  vs quote ${quote_spot:.4}  ({:+.0} %)",
        (realized_spot / quote_spot - 1.0) * 100.0
    );

    // ---- the verdict -----------------------------------------------------
    println!("\nverdict:");
    if realized_spot < quote_od {
        println!(
            "  spot still wins (${realized_spot:.4} < ${quote_od:.4}) — but by {:.0} %, not the {:.0} % the quote promised",
            (1.0 - realized_spot / quote_od) * 100.0,
            (1.0 - quote_spot / quote_od) * 100.0,
        );
    } else {
        println!(
            "  preemption ate the whole discount: realized ${realized_spot:.4} >= on-demand ${quote_od:.4}"
        );
    }
    println!(
        "  (this gap is what `blink advise --scenario spot` folds into its risk-adjusted ranking)"
    );
}
