//! Eviction-policy ablation: LRU vs LRC vs MRD (§2's related-work claim).
//!
//! ```bash
//! cargo run --release --example eviction_policies
//! ```
//!
//! The paper observes that DAG-aware eviction policies (MRD, LRC) do NOT
//! help the HiBench apps because most cache a single dataset — when every
//! partition belongs to the same RDD there is nothing smarter to evict.
//! This example verifies that on svm (single cached dataset, area A) and
//! then constructs a TWO-dataset workload with different reference
//! patterns where the policies do diverge.
//!
//! (Blink's answer to eviction is upstream of any policy: the advisor API
//! — see `examples/quickstart.rs` — sizes the cluster so nothing evicts.)

use blink::memory::EvictionPolicy;
use blink::metrics::RunSummary;
use blink::sim::{simulate, CachedData, ClusterSpec, SimOptions, WorkloadProfile};
use blink::workloads::{app_by_name, FULL_SCALE};

const POLICIES: [EvictionPolicy; 3] =
    [EvictionPolicy::Lru, EvictionPolicy::Lrc, EvictionPolicy::Mrd];

fn main() {
    // ---- part 1: svm in area A (4 machines < optimal 7) ----------------
    println!("svm @ 100 % on 4 machines (area A, single cached dataset):");
    let app = app_by_name("svm").unwrap();
    let mut base = None;
    for policy in POLICIES {
        let res = simulate(
            &app.profile(FULL_SCALE),
            &ClusterSpec::workers(4),
            SimOptions { policy, seed: 3, compute: None, detailed_log: false },
        )
        .unwrap();
        let s = RunSummary::from_log(&res.log);
        let t = s.duration_s / 60.0;
        let delta = base.map(|b: f64| (t - b) / b * 100.0).unwrap_or(0.0);
        base.get_or_insert(t);
        println!("  {policy}: {t:.1} min ({delta:+.2} % vs LRU)");
    }
    println!("  -> identical behaviour, as the paper reports (§2)\n");

    // ---- part 2: two cached datasets with skewed reference patterns ----
    println!("synthetic 2-dataset workload (hot 12 GB + cold 12 GB on 2 machines):");
    let profile = WorkloadProfile {
        name: "two-datasets".into(),
        scale: FULL_SCALE,
        input_mb: 8_000.0,
        parallelism: 256,
        cached: vec![
            // dataset 0: referenced every iteration (hot)
            CachedData { id: 0, true_total_mb: 12_000.0, measured_total_mb: 12_000.0 },
            // dataset 1: barely referenced again (cold)
            CachedData { id: 1, true_total_mb: 12_000.0, measured_total_mb: 12_000.0 },
        ],
        iterations: 12,
        compute_s_per_mb: 0.02,
        cached_speedup: 97.0,
        recompute_factor: 2.0,
        serial_s: 1.0,
        shuffle_mb: 50.0,
        exec_mem_total_mb: 500.0,
        task_overhead_s: 0.01,
        task_time_sigma: 0.1,
        sample_prep_s: 0.0,
    };
    for policy in POLICIES {
        let res = simulate(
            &profile,
            &ClusterSpec::workers(2),
            SimOptions { policy, seed: 3, compute: None, detailed_log: false },
        )
        .unwrap();
        let s = RunSummary::from_log(&res.log);
        println!(
            "  {policy}: {:.1} min, {} evictions, cached at end {:.1} GB",
            s.duration_s / 60.0,
            s.evictions,
            s.total_cached_mb() / 1024.0
        );
    }
    println!("  -> with multiple datasets the policies diverge, but per the");
    println!("     paper they mostly make the same decision; Blink instead");
    println!("     sizes the cluster so NO eviction happens at all.");
}
