//! Synthetic sweep: one advisor session over 100 seeded workloads.
//!
//! ```bash
//! cargo run --release --example synthetic_sweep
//! ```
//!
//! Generates 100 synthetic applications from the `mixed` preset
//! (`workloads::synth`), profiles each one through a single `Advisor`
//! session (one sampling phase per workload), answers the §5.4
//! recommendation and a catalog plan from every trained profile, and
//! cross-checks a sample of the fleet against the testkit's analytic
//! invariants — the "unbounded workload space" story of the differential
//! testkit, as a runnable demo.

use blink::blink::{Advisor, RustFit};
use blink::sim::{InstanceCatalog, MachineSpec};
use blink::testkit::{check_profile, MatrixSpec};
use blink::util::units::{fmt_mb, fmt_secs};
use blink::workloads::{SynthConfig, FULL_SCALE};

fn main() {
    const COUNT: usize = 100;
    const FIRST_SEED: u64 = 1;

    let cfg = SynthConfig::mixed();
    let catalog = InstanceCatalog::cloud();
    let pricing = blink::cost::PerInstanceHour::hourly();
    let worker = MachineSpec::worker_node();
    let spec = MatrixSpec::default();

    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().max_machines(12).build(&mut backend);

    println!("== synthetic sweep: {COUNT} workloads from preset '{}' ==\n", cfg.preset);
    let mut picks = [0usize; 13]; // histogram of §5.4 picks (1..=12)
    let mut eviction_free = 0usize;
    let mut uncached = 0usize;
    let mut sample_cost_total = 0.0;
    let mut checks = 0usize;
    let mut violations = Vec::new();

    for (seed, app) in cfg.generate_many(FIRST_SEED, COUNT) {
        let profile = advisor.profile(&app);
        let rec = profile.recommend(FULL_SCALE, &worker);
        let advice = profile.plan(FULL_SCALE, &catalog, &pricing);
        picks[rec.machines.min(12)] += 1;
        sample_cost_total += rec.sample_cost_machine_s;
        if profile.no_cached_data() {
            uncached += 1;
        }
        if let Some(best) = advice.plan.best() {
            if best.candidate.eviction_free {
                eviction_free += 1;
            }
        }
        // invariant-check every 10th workload (the full matrix lives in
        // rust/tests/synth.rs; this demo keeps the sweep fast)
        if (seed - FIRST_SEED) % 10 == 0 {
            let (c, v) = check_profile(&app, seed, &profile, &spec);
            checks += c;
            violations.extend(v);
        }
    }

    assert_eq!(
        advisor.sampling_phases(),
        COUNT,
        "one sampling phase per distinct workload, none re-paid"
    );

    println!("pick histogram (workers at 100 % scale):");
    for (n, count) in picks.iter().enumerate().skip(1) {
        if *count > 0 {
            println!("  {n:>2} machines: {:<40} {count}", "#".repeat(*count));
        }
    }
    println!("\nno-cached-data (atypical case 1) : {uncached}/{COUNT}");
    println!("eviction-free cloud plan          : {eviction_free}/{COUNT}");
    println!(
        "mean sampling cost                : {} per workload",
        fmt_secs(sample_cost_total / COUNT as f64)
    );
    println!(
        "mean predicted cached @100 %      : {}",
        fmt_mb(
            (0..COUNT as u64)
                .map(|i| {
                    advisor.profile(&cfg.generate(FIRST_SEED + i)).predicted_cached_mb(FULL_SCALE)
                })
                .sum::<f64>()
                / COUNT as f64
        )
    );
    assert_eq!(advisor.sampling_phases(), COUNT, "re-profiling hit the cache");

    println!("\ninvariants: {checks} checks on every 10th workload");
    for v in &violations {
        println!("  VIOLATION {v}");
    }
    assert!(violations.is_empty(), "analytic invariants must hold");
    println!("all green — the advisor generalizes beyond the paper's 16 rows");
}
