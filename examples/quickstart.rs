//! Quickstart: the full Blink pipeline on one application.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Runs three tiny sample runs of SVM (0.1–0.3 % of a 59.6 GB input) on a
//! simulated single sample node, fits the size/memory models, selects the
//! optimal cluster size, then executes the actual run at that size and
//! compares its cost against every other cluster size.

use blink::blink::{Blink, RustFit};
use blink::experiments::actual_run;
use blink::sim::MachineSpec;
use blink::util::units::{fmt_mb, fmt_pct, fmt_secs};
use blink::workloads::{app_by_name, FULL_SCALE};

fn main() {
    let app = app_by_name("svm").expect("svm registered");
    println!("== BLINK quickstart: {} ({} input) ==\n", app.name, fmt_mb(app.input_mb_full));

    // 1. sample + predict + select
    let mut backend = RustFit::default();
    let mut blink = Blink::new(&mut backend);
    let machine = MachineSpec::worker_node();
    let decision = blink.decide(&app, FULL_SCALE, &machine);

    println!("sample runs cost      : {}", fmt_secs(decision.sample_cost_machine_s));
    println!("predicted cached size : {}", fmt_mb(decision.predicted_cached_mb));
    println!("actual cached size    : {}", fmt_mb(app.total_true_cached_mb(FULL_SCALE)));
    println!("predicted exec memory : {}", fmt_mb(decision.predicted_exec_mb));
    println!("recommended cluster   : {} machines\n", decision.machines);

    // 2. the actual run at the recommendation, vs all other sizes
    println!("{:>4} {:>12} {:>16} {:>8}", "n", "time", "cost (m-min)", "");
    let mut costs = Vec::new();
    for n in 1..=12 {
        let s = actual_run(&app, FULL_SCALE, n, 42 + n as u64);
        let mark = if n == decision.machines { "<- pick" } else { "" };
        println!(
            "{:>4} {:>12} {:>16.1} {:>8}",
            n,
            fmt_secs(s.duration_s),
            s.cost_machine_min(),
            mark
        );
        costs.push(s.cost_machine_min());
    }
    let pick_cost = costs[decision.machines - 1] + decision.sample_cost_machine_s / 60.0;
    let avg = blink::util::stats::mean(&costs);
    println!(
        "\nBLINK total (incl. sampling) = {pick_cost:.1} machine-min = {} of the average cost",
        fmt_pct(pick_cost / avg)
    );
}
