//! Quickstart: the session-oriented Blink API on one application.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds an `Advisor`, profiles SVM **once** (three tiny sample runs of
//! 0.1–0.3 % of a 59.6 GB input on a simulated single sample node, model
//! fitting included), then answers two queries from the same trained
//! profile — the §5.4 cluster-size recommendation and the Table-2
//! max-scale bound — before executing the actual run at the pick and
//! comparing its cost against every other cluster size.

use blink::blink::report::RecommendReport;
use blink::blink::{Advisor, Report, RustFit};
use blink::experiments::actual_run;
use blink::sim::MachineSpec;
use blink::util::units::{fmt_mb, fmt_pct, fmt_secs};
use blink::workloads::{app_by_name, FULL_SCALE};

fn main() {
    let app = app_by_name("svm").expect("svm registered");
    println!("== BLINK quickstart: {} ({} input) ==\n", app.name, fmt_mb(app.input_mb_full));

    // 1. profile once: sample + fit (the only expensive step)
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().max_machines(12).build(&mut backend);
    let profile = advisor.profile(&app);
    let machine = MachineSpec::worker_node();

    // 2. query many: recommendation + bound from the same trained state
    let decision = profile.recommend(FULL_SCALE, &machine);
    println!("sample runs cost      : {}", fmt_secs(decision.sample_cost_machine_s));
    println!("predicted cached size : {}", fmt_mb(decision.predicted_cached_mb));
    println!("actual cached size    : {}", fmt_mb(app.total_true_cached_mb(FULL_SCALE)));
    println!("predicted exec memory : {}", fmt_mb(decision.predicted_exec_mb));
    println!("recommended cluster   : {} machines", decision.machines);
    println!(
        "max scale on 12 nodes : {:.0} (no new sample runs)",
        profile.max_scale(&machine, 12)
    );
    assert_eq!(advisor.sampling_phases(), 1, "both queries reused one profile");

    // every query result also renders as JSON for services:
    let report = RecommendReport::new("rust-nnls", &profile, FULL_SCALE, &machine, false);
    println!("\nas JSON: {}\n", report.to_json());

    // 3. the actual run at the recommendation, vs all other sizes
    println!("{:>4} {:>12} {:>16} {:>8}", "n", "time", "cost (m-min)", "");
    let mut costs = Vec::new();
    for n in 1..=12 {
        let s = actual_run(&app, FULL_SCALE, n, 42 + n as u64);
        let mark = if n == decision.machines { "<- pick" } else { "" };
        println!(
            "{:>4} {:>12} {:>16.1} {:>8}",
            n,
            fmt_secs(s.duration_s),
            s.cost_machine_min(),
            mark
        );
        costs.push(s.cost_machine_min());
    }
    let pick_cost = costs[decision.machines - 1] + decision.sample_cost_machine_s / 60.0;
    let avg = blink::util::stats::mean(&costs);
    println!(
        "\nBLINK total (incl. sampling) = {pick_cost:.1} machine-min = {} of the average cost",
        fmt_pct(pick_cost / avg)
    );
}
