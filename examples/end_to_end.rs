//! End-to-end driver: ALL THREE LAYERS composed on a real small workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```
//!
//! Unlike the Table-1 sweeps (analytic task costs — 59 GB does not fit a
//! laptop), every task body here REALLY runs the AOT-compiled Pallas
//! kernels through PJRT (L1/L2), orchestrated by the Rust coordinator and
//! simulator (L3), and Blink's predictor fits run through the compiled
//! `linfit` executable:
//!
//!   1. k-means at 2 % scale: sample, fit (PJRT linfit), select, then an
//!      actual run where each task executes a real Lloyd step on synthetic
//!      partition data — inertia is logged per iteration;
//!   2. svm at 0.5 % scale: same, with hinge-loss gradient steps — the
//!      loss curve is logged;
//!   3. reports the measured cached-read vs recompute asymmetry and the
//!      cost savings vs the average cluster size.
//!
//! Results are recorded in DESIGN.md §5 (experiment index).

use blink::blink::Advisor;
use blink::compute::RealCompute;
use blink::memory::EvictionPolicy;
use blink::metrics::RunSummary;
use blink::runtime::{artifacts_available, PjrtFit, Runtime};
use blink::sim::{simulate, ClusterSpec, MachineSpec, SimOptions};
use blink::util::units::{fmt_mb, fmt_pct, fmt_secs};
use blink::workloads::app_by_name;

fn main() {
    if !artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let mut runtime = Runtime::from_repo_root().expect("PJRT runtime");
    println!("PJRT platform: {}", runtime.platform());
    println!("artifacts: {:?}\n", runtime.artifact_names());

    // --- loss curves: prove the kernels compute something real ----------
    for (app, iters) in [("km", 8), ("svm", 8)] {
        let mut rc = RealCompute::new(&mut runtime, app, 7);
        print!("{app} kernel, loss/inertia per pass:");
        for _ in 0..iters {
            let loss = rc.one_pass().expect("kernel pass");
            print!(" {loss:.4}");
        }
        println!();
    }
    println!();

    for (name, scale) in [("km", 20.0), ("svm", 5.0)] {
        run_real(&mut runtime, name, scale);
    }

    // --- area-A physics with real compute: a memory-starved node ---------
    // Shrink the executor heap until only part of the cached dataset fits;
    // the uncached partitions are REALLY recomputed (4 kernel passes each)
    // in every iteration, demonstrating the asymmetry the paper measures.
    println!("== constrained node: svm @ scale 5 on a 1 GB-heap machine ==");
    let app = app_by_name("svm").unwrap();
    let mut profile = app.profile(5.0);
    profile.iterations = 4;
    let mut starved = ClusterSpec::workers(1);
    starved.machine.heap_mb = 640.0; // M ~ 204 MB < 215 MB cache -> partial
    let mut rc = RealCompute::new(&mut runtime, "svm", 13);
    let res = simulate(
        &profile,
        &starved,
        SimOptions {
            policy: EvictionPolicy::Lru,
            seed: 2,
            compute: Some(&mut rc),
            detailed_log: true,
        },
    )
    .unwrap();
    let (mut ct, mut nc, mut rt_, mut nr) = (0.0, 0usize, 0.0, 0usize);
    for e in &res.log.events {
        if let blink::metrics::Event::TaskEnd { stage, duration_s, cached_read, .. } = e {
            if *stage == 0 {
                continue;
            }
            if *cached_read {
                ct += duration_s;
                nc += 1;
            } else {
                rt_ += duration_s;
                nr += 1;
            }
        }
    }
    println!(
        "cached fraction after load: {:.0} %",
        res.cached_fraction_after_load * 100.0
    );
    assert!(nr > 0, "starved node must recompute");
    let ratio = (rt_ / nr as f64) / (ct / nc as f64).max(1e-12);
    println!(
        "MEASURED recompute/cached wall-time ratio: {ratio:.1}x ({nc} cached, {nr} recomputed)"
    );
    println!("(the paper measures ~97x on Spark; here recompute = 4 kernel passes + I/O)");
}

fn run_real(runtime: &mut Runtime, name: &str, scale: f64) {
    let app = app_by_name(name).unwrap();
    println!(
        "== end-to-end {name} @ scale {scale} ({} of input) ==",
        fmt_mb(app.input_mb(scale))
    );

    // 1. Blink decision with the PJRT linfit backend (L1 on the hot path)
    let machine = MachineSpec::worker_node();
    let t0 = std::time::Instant::now();
    let (decision, dispatches) = {
        let mut fit = PjrtFit::new(runtime);
        let mut advisor = Advisor::builder().build(&mut fit);
        assert_eq!(advisor.backend_name(), "pjrt-linfit");
        let d = advisor.profile(&app).recommend(scale, &machine);
        // the advisor borrows fit; read the dispatch count after
        drop(advisor);
        (d, fit.dispatches)
    };
    println!(
        "decision: {} machines (predicted cache {}, {} PJRT linfit dispatches, {:.1} ms)",
        decision.machines,
        fmt_mb(decision.predicted_cached_mb),
        dispatches,
        t0.elapsed().as_secs_f64() * 1e3
    );

    // 2. actual run where tasks execute real kernels through PJRT
    let mut profile = app.profile(scale);
    profile.iterations = profile.iterations.min(6); // keep the demo short
    let mut rc = RealCompute::new(runtime, name, 11);
    let wall = std::time::Instant::now();
    let res = simulate(
        &profile,
        &ClusterSpec::workers(decision.machines),
        SimOptions {
            policy: EvictionPolicy::Lru,
            seed: 1,
            compute: Some(&mut rc),
            detailed_log: true,
        },
    )
    .unwrap();
    let kernel_tasks = rc.tasks_run;
    let s = RunSummary::from_log(&res.log);
    println!(
        "actual run: {} tasks ({} kernel-backed), sim time {}, wall {}, {} evictions",
        s.tasks,
        kernel_tasks,
        fmt_secs(s.duration_s),
        fmt_secs(wall.elapsed().as_secs_f64()),
        s.evictions
    );

    // 3. measured cached vs recompute asymmetry from the event log
    let (mut cached_t, mut nc, mut recompute_t, mut nr) = (0.0, 0usize, 0.0, 0usize);
    for e in &res.log.events {
        if let blink::metrics::Event::TaskEnd { stage, duration_s, cached_read, .. } = e {
            if *stage == 0 {
                continue;
            }
            if *cached_read {
                cached_t += duration_s;
                nc += 1;
            } else {
                recompute_t += duration_s;
                nr += 1;
            }
        }
    }
    if nc > 0 && nr > 0 {
        let ratio = (recompute_t / nr as f64) / (cached_t / nc as f64);
        println!(
            "measured recompute/cached task-time ratio: {ratio:.1}x ({} cached, {} recomputed)",
            nc, nr
        );
    } else {
        println!("fully cached run ({nc} cached reads) — no recompute tasks (as selected)");
    }
    println!(
        "throughput: {:.0} kernel tasks/s of wall time",
        kernel_tasks as f64 / wall.elapsed().as_secs_f64()
    );
    println!(
        "sampling overhead vs this run: {}\n",
        fmt_pct(decision.sample_cost_machine_s / s.cost_machine_s.max(1e-9))
    );
}
