//! Scalability & model reuse (§5.4 / §6.4): the predictors are trained
//! ONCE from tiny sample runs, then re-queried for other data scales and
//! other machine types without any new sampling.
//!
//! ```bash
//! cargo run --release --example scalability
//! ```

use blink::blink::{
    bounds, select_cluster_size, ExecMemoryPredictor, RustFit, SampleRunsManager,
    SamplingOutcome, SizePredictor,
};
use blink::sim::MachineSpec;
use blink::util::units::fmt_mb;
use blink::workloads::{app_by_name, FULL_SCALE};

fn main() {
    let app = app_by_name("svm").unwrap();
    println!("training predictors from 3 sample runs (0.1–0.3 % of {})...\n",
        fmt_mb(app.input_mb_full));

    let mgr = SampleRunsManager::default();
    let runs = match mgr.run(&app, &[1.0, 2.0, 3.0]) {
        SamplingOutcome::Profiled(r) => r,
        _ => unreachable!("svm caches data"),
    };
    let mut backend = RustFit::default();
    let sizes = SizePredictor::train(&mut backend, &runs);
    let exec = ExecMemoryPredictor::train(&mut backend, &runs);

    // ---- same machine type, growing data scale --------------------------
    let worker = MachineSpec::worker_node();
    println!("cluster size vs data scale (worker nodes, NO new sample runs):");
    println!("{:>8} {:>12} {:>12} {:>6}", "scale", "input", "pred cache", "PICK");
    for mult in [0.25, 0.5, 1.0, 1.5, 2.0, 3.0] {
        let scale = FULL_SCALE * mult;
        let cached = sizes.predict_total(scale);
        let sel = select_cluster_size(cached, exec.predict_total(scale), &worker, 64);
        println!(
            "{:>7.0}% {:>12} {:>12} {:>6}",
            mult * 100.0,
            fmt_mb(app.input_mb(scale)),
            fmt_mb(cached),
            sel.machines
        );
    }

    // ---- same scale, different machine types ----------------------------
    println!("\ncluster size vs machine type @ 100 % (same models):");
    let mut big = MachineSpec::worker_node();
    big.heap_mb *= 2.0; // a hypothetical 32 GB instance type
    let mut small = MachineSpec::sample_node();
    small.heap_mb = 6.0 * 1024.0;
    for (name, m) in [("sample-node 6G", &small), ("worker 12G", &worker), ("worker 24G", &big)] {
        let sel = select_cluster_size(
            sizes.predict_total(FULL_SCALE),
            exec.predict_total(FULL_SCALE),
            m,
            64,
        );
        println!(
            "  {:<16} M={:>9} -> {:>3} machines",
            name,
            fmt_mb(m.unified_mb()),
            sel.machines
        );
    }

    // ---- cluster bounds (Table 2's question) -----------------------------
    println!("\nmax eviction-free data scale on a fixed cluster (worker nodes):");
    for n in [4, 8, 12] {
        let s = bounds::max_scale(&sizes, &exec, &worker, n, 1e-5);
        println!(
            "  {n:>2} machines: scale {:>7.0} ({} of input)",
            s,
            fmt_mb(app.input_mb(s))
        );
    }
}
