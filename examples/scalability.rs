//! Scalability & model reuse (§5.4 / §6.4): one `TrainedProfile` is built
//! from tiny sample runs, then re-queried for other data scales and other
//! machine types without any new sampling — profile once, query many.
//!
//! ```bash
//! cargo run --release --example scalability
//! ```

use blink::blink::{Advisor, RustFit};
use blink::sim::MachineSpec;
use blink::util::units::fmt_mb;
use blink::workloads::{app_by_name, FULL_SCALE};

fn main() {
    let app = app_by_name("svm").unwrap();
    println!("training a profile from 3 sample runs (0.1–0.3 % of {})...\n",
        fmt_mb(app.input_mb_full));

    let mut backend = RustFit::default();
    // 64 machines: let the queries roam beyond the paper's 12-node testbed
    let mut advisor =
        Advisor::builder().max_machines(64).scales(&[1.0, 2.0, 3.0]).build(&mut backend);
    let profile = advisor.profile(&app);

    // ---- same machine type, growing data scale --------------------------
    let worker = MachineSpec::worker_node();
    println!("cluster size vs data scale (worker nodes, NO new sample runs):");
    println!("{:>8} {:>12} {:>12} {:>6}", "scale", "input", "pred cache", "PICK");
    for mult in [0.25, 0.5, 1.0, 1.5, 2.0, 3.0] {
        let scale = FULL_SCALE * mult;
        let d = profile.recommend(scale, &worker);
        println!(
            "{:>7.0}% {:>12} {:>12} {:>6}",
            mult * 100.0,
            fmt_mb(app.input_mb(scale)),
            fmt_mb(d.predicted_cached_mb),
            d.machines
        );
    }

    // ---- same scale, different machine types ----------------------------
    println!("\ncluster size vs machine type @ 100 % (same profile):");
    let mut big = MachineSpec::worker_node();
    big.heap_mb *= 2.0; // a hypothetical 32 GB instance type
    let mut small = MachineSpec::sample_node();
    small.heap_mb = 6.0 * 1024.0;
    for (name, m) in [("sample-node 6G", &small), ("worker 12G", &worker), ("worker 24G", &big)] {
        let d = profile.recommend(FULL_SCALE, m);
        println!(
            "  {:<16} M={:>9} -> {:>3} machines",
            name,
            fmt_mb(m.unified_mb()),
            d.machines
        );
    }

    // ---- cluster bounds (Table 2's question) -----------------------------
    println!("\nmax eviction-free data scale on a fixed cluster (worker nodes):");
    for n in [4, 8, 12] {
        let s = profile.max_scale(&worker, n);
        println!(
            "  {n:>2} machines: scale {:>7.0} ({} of input)",
            s,
            fmt_mb(app.input_mb(s))
        );
    }
    println!(
        "\n(total sampling phases this session: {} — every answer above reused it)",
        advisor.sampling_phases()
    );
}
