//! Cluster advisor: Blink recommendations for every workload, including
//! the machines_min/machines_max bracket and headroom diagnostics — the
//! report an operator would consult before submitting a job.
//!
//! ```bash
//! cargo run --release --example cluster_advisor [-- <scale>]
//! ```

use blink::blink::{Blink, RustFit};
use blink::sim::MachineSpec;
use blink::util::units::{fmt_mb, fmt_secs};
use blink::workloads::{all_apps, FULL_SCALE};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(FULL_SCALE);
    let machine = MachineSpec::worker_node();
    println!(
        "cluster advisor @ data scale {scale} — machine type: {} cores, {} heap (M={}, R={})\n",
        machine.cores,
        fmt_mb(machine.heap_mb),
        fmt_mb(machine.unified_mb()),
        fmt_mb(machine.storage_floor_mb()),
    );
    println!(
        "{:<7} {:>10} {:>12} {:>12} {:>5} {:>5} {:>6} {:>12} {:>12}",
        "app", "input", "pred cache", "pred exec", "min", "max", "PICK", "headroom", "sample cost"
    );
    for app in all_apps() {
        let mut backend = RustFit::default();
        let mut blink = Blink::new(&mut backend);
        let scales = blink::experiments::sampling_scales(&app);
        let d = blink.decide_with_scales(&app, scale, &machine, &scales);
        let (min, max, headroom) = d
            .selection
            .as_ref()
            .map(|s| (s.machines_min, s.machines_max, s.headroom_mb))
            .unwrap_or((1, 1, 0.0));
        println!(
            "{:<7} {:>10} {:>12} {:>12} {:>5} {:>5} {:>6} {:>12} {:>12}",
            app.name,
            fmt_mb(app.input_mb(scale)),
            fmt_mb(d.predicted_cached_mb),
            fmt_mb(d.predicted_exec_mb),
            min,
            max,
            d.machines,
            fmt_mb(headroom),
            fmt_secs(d.sample_cost_machine_s),
        );
    }
    println!("\n(PICK = minimal eviction-free cluster size; headroom = spare cache per machine)");
}
