//! Cluster advisor: Blink recommendations for every workload, then the
//! fleet-aware planner's multi-catalog report — the paper's single-type
//! answer side by side with the catalog-driven (type × count) search an
//! operator would consult before submitting a job.
//!
//! ```bash
//! cargo run --release --example cluster_advisor [-- <scale> [app]]
//! ```

use blink::blink::{
    plan, Blink, ExecMemoryPredictor, PlanInput, RustFit, SampleRunsManager, SamplingOutcome,
    SizePredictor,
};
use blink::cost::{PerInstanceHour, PricingModel, SpotDiscount};
use blink::sim::{InstanceCatalog, MachineSpec};
use blink::util::units::{fmt_mb, fmt_mb_signed, fmt_secs};
use blink::workloads::{all_apps, app_by_name, FULL_SCALE};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(FULL_SCALE);
    let focus = std::env::args().nth(2).unwrap_or_else(|| "als".to_string());
    let machine = MachineSpec::worker_node();
    println!(
        "cluster advisor @ data scale {scale} — machine type: {} cores, {} heap (M={}, R={})\n",
        machine.cores,
        fmt_mb(machine.heap_mb),
        fmt_mb(machine.unified_mb()),
        fmt_mb(machine.storage_floor_mb()),
    );
    println!(
        "{:<7} {:>10} {:>12} {:>12} {:>5} {:>5} {:>6} {:>14} {:>12}",
        "app", "input", "pred cache", "pred exec", "min", "max", "PICK", "headroom", "sample cost"
    );
    for app in all_apps() {
        let mut backend = RustFit::default();
        let mut blink = Blink::new(&mut backend);
        let scales = blink::experiments::sampling_scales(&app);
        let d = blink.decide_with_scales(&app, scale, &machine, &scales);
        // headroom_mb is negative (a deficit) for saturated picks; the
        // signed rendering keeps that visible instead of faking headroom
        let (min, max, headroom) = d
            .selection
            .as_ref()
            .map(|s| (s.machines_min, s.machines_max, s.headroom_mb))
            .unwrap_or((1, 1, 0.0));
        println!(
            "{:<7} {:>10} {:>12} {:>12} {:>5} {:>5} {:>6} {:>14} {:>12}",
            app.name,
            fmt_mb(app.input_mb(scale)),
            fmt_mb(d.predicted_cached_mb),
            fmt_mb(d.predicted_exec_mb),
            min,
            max,
            d.machines,
            fmt_mb_signed(headroom),
            fmt_secs(d.sample_cost_machine_s),
        );
    }
    println!("\n(PICK = minimal eviction-free cluster size; negative headroom = cache deficit)");

    // ---- fleet-aware planning: ONE sampling phase, every catalog ---------
    // §5.4's adaptivity: the predictors are trained once from the sample
    // runs, then re-planned across catalogs and pricing models for free.
    let app = app_by_name(&focus).unwrap_or_else(|| {
        eprintln!("unknown app '{focus}', falling back to als");
        app_by_name("als").unwrap()
    });
    println!("\n=== fleet planner for '{}' @ scale {scale} ===", app.name);
    let mgr = SampleRunsManager::default();
    let scales = blink::experiments::sampling_scales(&app);
    let (cached, exec_mb) = match mgr.run(&app, &scales) {
        SamplingOutcome::Profiled(runs) => {
            let mut backend = RustFit::default();
            let sizes = SizePredictor::train(&mut backend, &runs);
            let exec = ExecMemoryPredictor::train(&mut backend, &runs);
            (sizes.predict_total(scale), exec.predict_total(scale))
        }
        SamplingOutcome::NoCachedData { .. } => (0.0, 0.0),
    };
    let profile = app.profile(scale);
    let input = PlanInput { profile: &profile, cached_total_mb: cached, exec_total_mb: exec_mb };
    let hourly = PerInstanceHour::hourly();
    let spot = SpotDiscount::typical();
    let pricings: [&dyn PricingModel; 2] = [&hourly, &spot];
    for catalog in [InstanceCatalog::paper(), InstanceCatalog::cloud()] {
        for pricing in pricings {
            let p = plan(&input, &catalog, pricing, 12);
            blink::experiments::report::print_plan(&p, &catalog, pricing.name());
        }
    }
    println!("\n(one sampling phase total; the same predictors priced every catalog — §5.4's adaptivity)");
}
