//! Cluster advisor: Blink recommendations for every workload, then the
//! fleet-aware planner's multi-catalog report — the paper's single-type
//! answer side by side with the catalog-driven (type × count) search an
//! operator would consult before submitting a job.
//!
//! One long-lived `Advisor` session serves the whole report: each app is
//! profiled exactly once, and the focus app's single `TrainedProfile`
//! answers every catalog × pricing plan — §5.4's adaptivity as API shape.
//!
//! ```bash
//! cargo run --release --example cluster_advisor [-- <scale> [app]]
//! ```

use blink::blink::{Advisor, RustFit};
use blink::cost::{PerInstanceHour, PricingModel, SpotDiscount};
use blink::sim::{InstanceCatalog, MachineSpec};
use blink::util::units::{fmt_mb, fmt_mb_signed, fmt_secs};
use blink::workloads::{all_apps, app_by_name, FULL_SCALE};

fn main() {
    let scale: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(FULL_SCALE);
    let focus = std::env::args().nth(2).unwrap_or_else(|| "als".to_string());
    let machine = MachineSpec::worker_node();
    println!(
        "cluster advisor @ data scale {scale} — machine type: {} cores, {} heap (M={}, R={})\n",
        machine.cores,
        fmt_mb(machine.heap_mb),
        fmt_mb(machine.unified_mb()),
        fmt_mb(machine.storage_floor_mb()),
    );

    // one session for the whole report; profiles are cached per app
    let mut backend = RustFit::default();
    let mut advisor = Advisor::builder().max_machines(12).build(&mut backend);

    println!(
        "{:<7} {:>10} {:>12} {:>12} {:>5} {:>5} {:>6} {:>14} {:>12}",
        "app", "input", "pred cache", "pred exec", "min", "max", "PICK", "headroom", "sample cost"
    );
    for app in all_apps() {
        let d = advisor.profile(&app).recommend(scale, &machine);
        // headroom_mb is negative (a deficit) for saturated picks; the
        // signed rendering keeps that visible instead of faking headroom
        let (min, max, headroom) = d
            .selection
            .as_ref()
            .map(|s| (s.machines_min, s.machines_max, s.headroom_mb))
            .unwrap_or((1, 1, 0.0));
        println!(
            "{:<7} {:>10} {:>12} {:>12} {:>5} {:>5} {:>6} {:>14} {:>12}",
            app.name,
            fmt_mb(app.input_mb(scale)),
            fmt_mb(d.predicted_cached_mb),
            fmt_mb(d.predicted_exec_mb),
            min,
            max,
            d.machines,
            fmt_mb_signed(headroom),
            fmt_secs(d.sample_cost_machine_s),
        );
    }
    println!("\n(PICK = minimal eviction-free cluster size; negative headroom = cache deficit)");

    // ---- fleet-aware planning: ONE sampling phase, every catalog ---------
    // §5.4's adaptivity: the profile is trained once from the sample runs
    // (a cache hit here — the table above already profiled it), then
    // re-planned across catalogs and pricing models for free.
    let app = app_by_name(&focus).unwrap_or_else(|| {
        eprintln!("unknown app '{focus}', falling back to als");
        app_by_name("als").unwrap()
    });
    println!("\n=== fleet planner for '{}' @ scale {scale} ===", app.name);
    let phases_before = advisor.sampling_phases();
    let profile = advisor.profile(&app);
    let hourly = PerInstanceHour::hourly();
    let spot = SpotDiscount::typical();
    let pricings: [&dyn PricingModel; 2] = [&hourly, &spot];
    for catalog in [InstanceCatalog::paper(), InstanceCatalog::cloud()] {
        for pricing in pricings {
            let advice = profile.plan(scale, &catalog, pricing);
            blink::experiments::report::print_plan(&advice.plan, &catalog, pricing.name());
        }
    }
    assert_eq!(advisor.sampling_phases(), phases_before, "plans must not re-sample");
    println!("\n(one sampling phase; the same profile priced every catalog — §5.4's adaptivity)");
}
