"""L2: the JAX compute graphs that get AOT-lowered for the Rust coordinator.

Each public function here is a pure jax function built on the L1 Pallas
kernels in ``kernels/``. ``aot.py`` lowers every entry in ``EXPORTS`` once
(fixed shapes, listed in the manifest) to HLO text; the Rust runtime loads
and executes them via PJRT. Python never runs on the request path.
"""

import jax.numpy as jnp

from .kernels import linfit as linfit_k
from .kernels import ml_steps

# Hyper-parameters are baked into the AOT artifact (one executable per model
# variant, as per the architecture); the Rust side scales data instead.
SVM_LR, SVM_REG = 0.1, 1e-3
LOGREG_LR, LOGREG_REG = 0.1, 1e-3


def predictor_fit(x, y, mask):
    """Blink's prediction phase: batched NNLS fit + residual RMSE.

    One batch element per (cached-dataset x candidate-model x CV-fold);
    the Rust coordinator builds the design matrices / fold masks and does
    model selection on the returned RMSEs.
    """
    theta, rmse = linfit_k.linfit(x, y, mask)
    return theta, rmse


def svm_iteration(x, y, w):
    """One full hinge-loss gradient-descent step over a partition."""
    gsum, lsum = ml_steps.svm_grad_sums(x, y, w)
    t = jnp.asarray(x.shape[0], x.dtype)
    grad = gsum / t + SVM_REG * w
    loss = lsum[0] / t + 0.5 * SVM_REG * jnp.sum(w * w)
    return w - SVM_LR * grad, loss


def logreg_iteration(x, y, w):
    """One full logistic-regression gradient-descent step over a partition."""
    gsum, lsum = ml_steps.logistic_grad_sums(x, y, w)
    t = jnp.asarray(x.shape[0], x.dtype)
    grad = gsum / t + LOGREG_REG * w
    loss = lsum[0] / t + 0.5 * LOGREG_REG * jnp.sum(w * w)
    return w - LOGREG_LR * grad, loss


def kmeans_iteration(x, c):
    """One Lloyd iteration over a partition (empty clusters keep centroids)."""
    sums, counts, inertia = ml_steps.kmeans_stats(x, c)
    c_next = jnp.where(counts[:, None] > 0,
                       sums / jnp.maximum(counts, 1.0)[:, None], c)
    t = jnp.asarray(x.shape[0], x.dtype)
    return c_next, inertia[0] / t


def _f32(*shape):
    import jax
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# name -> (fn, example_args). Shapes are the AOT contract with rust/src/runtime.
EXPORTS = {
    "linfit": (
        predictor_fit,
        (
            _f32(linfit_k.BATCH, linfit_k.POINTS, linfit_k.FEATURES),
            _f32(linfit_k.BATCH, linfit_k.POINTS),
            _f32(linfit_k.BATCH, linfit_k.POINTS),
        ),
    ),
    "svm_step": (
        svm_iteration,
        (
            _f32(ml_steps.SVM_ROWS, ml_steps.SVM_DIM),
            _f32(ml_steps.SVM_ROWS),
            _f32(ml_steps.SVM_DIM),
        ),
    ),
    "logreg_step": (
        logreg_iteration,
        (
            _f32(ml_steps.SVM_ROWS, ml_steps.SVM_DIM),
            _f32(ml_steps.SVM_ROWS),
            _f32(ml_steps.SVM_DIM),
        ),
    ),
    "kmeans_step": (
        kmeans_iteration,
        (
            _f32(ml_steps.KM_ROWS, ml_steps.KM_DIM),
            _f32(ml_steps.KM_K, ml_steps.KM_DIM),
        ),
    ),
}
