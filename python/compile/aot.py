"""AOT lowering: every EXPORTS entry in model.py -> artifacts/<name>.hlo.txt.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

A ``manifest.json`` alongside the artifacts records each executable's
input/output shapes + the tuple convention so the Rust runtime can validate
its buffers at load time. Runs once from ``make artifacts``; never at
request time.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "return_tuple": True, "entries": {}}
    for name, (fn, example_args) in model.EXPORTS.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *example_args)
        leaves = jax.tree_util.tree_leaves(out_avals)
        manifest["entries"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [_shape_entry(s) for s in example_args],
            "outputs": [_shape_entry(s) for s in leaves],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
