"""L1 Pallas kernels: per-iteration compute of the HiBench-style workloads.

These kernels are the task bodies executed by the simulator's RealCompute
mode (examples/end_to_end.rs): a Spark task that "computes a partition" runs
one of these over that partition's rows, so the cached-vs-recomputed cost
asymmetry the paper measures (97x, Section 1) is exercised with real compute
rather than an analytic constant.

TPU mapping: the grid tiles the row (sample) dimension; each program pulls a
[TILE_T, D] block of the partition from HBM into VMEM (BlockSpec below),
performs MXU-shaped [TILE_T, D] x [D] products, and accumulates the reduced
gradient / centroid statistics into a single VMEM-resident output block that
every grid step revisits (TPU grids execute sequentially, so `+=` after a
first-step init is the canonical reduction idiom). interpret=True on this
image.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed AOT shapes (padded by the Rust caller; see artifacts/manifest.json).
SVM_ROWS, SVM_DIM = 4096, 64
KM_ROWS, KM_DIM, KM_K = 4096, 16, 8
TILE_T = 256


def _svm_grad_kernel(x_ref, y_ref, w_ref, gsum_ref, loss_ref):
    """Hinge-loss subgradient + loss, accumulated across row tiles.

    x_ref: [TILE_T, D], y_ref: [TILE_T], w_ref: [D]
    gsum_ref: [D] (sum over rows of -y*x*1[margin<1]), loss_ref: [1] (sum).
    """
    x = x_ref[...]
    y = y_ref[...]
    w = w_ref[...]

    margin = y * (x @ w)                                   # [TILE_T]
    active = jnp.where(margin < 1.0, 1.0, 0.0).astype(x.dtype)
    gpart = -(x * (y * active)[:, None]).sum(axis=0)       # [D]
    lpart = jnp.maximum(0.0, 1.0 - margin).sum()

    @pl.when(pl.program_id(0) == 0)
    def _init():
        gsum_ref[...] = jnp.zeros_like(gsum_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    gsum_ref[...] += gpart
    loss_ref[...] += lpart[None]


def _logistic_grad_kernel(x_ref, y_ref, w_ref, gsum_ref, loss_ref):
    """Logistic-loss gradient + stable NLL, accumulated across row tiles."""
    x = x_ref[...]
    y = y_ref[...]
    w = w_ref[...]

    z = x @ w
    p = 1.0 / (1.0 + jnp.exp(-z))
    gpart = (x * (p - y)[:, None]).sum(axis=0)
    lpart = (jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))).sum()

    @pl.when(pl.program_id(0) == 0)
    def _init():
        gsum_ref[...] = jnp.zeros_like(gsum_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    gsum_ref[...] += gpart
    loss_ref[...] += lpart[None]


def _kmeans_kernel(x_ref, c_ref, sums_ref, counts_ref, inertia_ref):
    """Lloyd-step statistics (cluster sums / counts / total squared dist)."""
    x = x_ref[...]                                          # [TILE_T, D]
    c = c_ref[...]                                          # [K, D]

    d2 = ((x[:, None, :] - c[None, :, :]) ** 2).sum(-1)     # [TILE_T, K]
    assign = jnp.argmin(d2, axis=-1)
    onehot = (assign[:, None] == jnp.arange(c.shape[0])[None, :]).astype(x.dtype)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        inertia_ref[...] = jnp.zeros_like(inertia_ref)

    sums_ref[...] += onehot.T @ x                           # [K, D] MXU
    counts_ref[...] += onehot.sum(axis=0)
    inertia_ref[...] += jnp.min(d2, axis=-1).sum()[None]


def _row_tiled_call(kernel, x, row_args, bcast_args, out_shapes):
    """Shared pallas_call wiring: tile rows, broadcast params, reduce outs."""
    t, d = x.shape
    assert t % TILE_T == 0, (t, TILE_T)
    grid = (t // TILE_T,)
    in_specs = [pl.BlockSpec((TILE_T, d), lambda i: (i, 0))]
    for a in row_args:
        in_specs.append(pl.BlockSpec((TILE_T,) + a.shape[1:],
                                     lambda i: (i,) + (0,) * (a.ndim - 1)))
    for a in bcast_args:
        in_specs.append(pl.BlockSpec(a.shape, lambda i, nd=a.ndim: (0,) * nd))
    out_specs = [pl.BlockSpec(s.shape, lambda i, nd=len(s.shape): (0,) * nd)
                 for s in out_shapes]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=True,
    )(x, *row_args, *bcast_args)


@jax.jit
def svm_grad_sums(x, y, w):
    """Returns (grad_sum [D], hinge_loss_sum [1]) over all rows of x."""
    outs = [jax.ShapeDtypeStruct(w.shape, x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype)]
    return _row_tiled_call(_svm_grad_kernel, x, [y], [w], outs)


@jax.jit
def logistic_grad_sums(x, y, w):
    """Returns (grad_sum [D], nll_sum [1]) over all rows of x."""
    outs = [jax.ShapeDtypeStruct(w.shape, x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype)]
    return _row_tiled_call(_logistic_grad_kernel, x, [y], [w], outs)


@jax.jit
def kmeans_stats(x, c):
    """Returns (cluster_sums [K, D], counts [K], inertia_sum [1])."""
    k, d = c.shape
    outs = [jax.ShapeDtypeStruct((k, d), x.dtype),
            jax.ShapeDtypeStruct((k,), x.dtype),
            jax.ShapeDtypeStruct((1,), x.dtype)]
    return _row_tiled_call(_kmeans_kernel, x, [], [c], outs)
