"""L1 Pallas kernel: batched non-negative least squares (projected gradient).

This is the hot spot of Blink's prediction phase: after the sample runs, the
size predictor and the execution-memory predictor each fit a zoo of candidate
models (linear, affine-in-sqrt, quadratic, ...) with leave-one-out
cross-validation. Expressing every (candidate model x CV fold x dataset)
fit as one batched NNLS problem lets the whole prediction phase lower into a
single HLO module that the Rust coordinator executes once per application.

TPU mapping (cf. DESIGN.md #Hardware-Adaptation): the grid walks the batch
dimension; each program owns one tiny [N, K] design matrix resident in VMEM
(N <= 16, K <= 4 -> well under a single VMEM tile), computes the [K, K] Gram
matrix with an MXU-shaped contraction and runs a fixed-trip-count projected
gradient loop entirely out of registers/VMEM. There is no HBM traffic inside
the loop. On this image the kernel runs under ``interpret=True`` (CPU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed AOT shapes (padded by the Rust caller; see artifacts/manifest.json).
BATCH = 64     # candidate-models x folds x cached-datasets, padded
POINTS = 16    # max sample runs per fit (paper uses 3..10), padded
FEATURES = 4   # max model features (1, s, s^2 / sqrt(s) / log(s)), padded
PGD_ITERS = 300


def _linfit_kernel(x_ref, y_ref, mask_ref, theta_ref, rmse_ref, *, iters):
    """One NNLS problem per grid step.

    x_ref:    [1, N, K] design matrix block
    y_ref:    [1, N]    labels
    mask_ref: [1, N]    row weights (0 excludes a row -> CV folds, padding)
    theta_ref:[1, K]    out: non-negative coefficients
    rmse_ref: [1, 1]    out: residual RMSE over active rows
    """
    x = x_ref[0]                                  # [N, K]
    y = y_ref[0]                                  # [N]
    m = mask_ref[0]                               # [N]

    xw = x * m[:, None]                           # weighted rows
    gram = xw.T @ x                               # [K, K]  (MXU contraction)
    rhs = xw.T @ y                                # [K]

    # Lipschitz bound of the quadratic: row-sum norm of the Gram matrix.
    lip = jnp.max(jnp.sum(jnp.abs(gram), axis=-1))
    eta = 1.0 / jnp.maximum(lip, 1e-12)

    # FISTA (accelerated projected gradient): same KKT point as plain PGD
    # but far fewer iterations on the ill-conditioned quadratic/log feature
    # families — mirrors rust/src/linalg exactly.
    def body(_, carry):
        theta, momentum, t = carry
        grad = gram @ momentum - rhs
        nxt = jnp.maximum(momentum - eta * grad, 0.0)
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        beta = (t - 1.0) / t_next
        return nxt, nxt + beta * (nxt - theta), t_next

    zero = jnp.zeros_like(rhs)
    theta, _, _ = jax.lax.fori_loop(0, iters, body, (zero, zero, jnp.float32(1.0)))

    pred = x @ theta                              # [N]
    se = m * (pred - y) ** 2
    n = jnp.maximum(jnp.sum(m), 1.0)
    rmse = jnp.sqrt(jnp.sum(se) / n)

    theta_ref[0] = theta
    rmse_ref[0, 0] = rmse


@functools.partial(jax.jit, static_argnames=("iters",))
def linfit(x, y, mask, iters: int = PGD_ITERS):
    """Batched NNLS fit + residual RMSE.

    Args:
      x:    [B, N, K] design matrices.
      y:    [B, N]    labels.
      mask: [B, N]    row weights.

    Returns:
      (theta [B, K], rmse [B]).
    """
    b, n, k = x.shape
    theta, rmse = pl.pallas_call(
        functools.partial(_linfit_kernel, iters=iters),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), x.dtype),
            jax.ShapeDtypeStruct((b, 1), x.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y, mask)
    return theta, rmse[:, 0]
