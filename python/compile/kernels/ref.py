"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the CORE correctness signal: pytest asserts each Pallas kernel
(run under ``interpret=True``) matches its oracle here to tight tolerances,
over randomized shape/dtype sweeps. Keep these boring and obviously right.
"""

import jax.numpy as jnp


def nnls_fit(x, y, mask, iters: int = 300):
    """Batched non-negative least squares via FISTA (accelerated projected
    gradient) on the normal equations.

    Solves ``argmin_{theta >= 0} || diag(mask) (x @ theta - y) ||_2`` for a
    batch of small design matrices. This is the estimator family the paper
    uses (scipy ``curve_fit`` with enforced positive bounds, Eq. 1) —
    projected gradient converges to the same KKT point for these tiny
    convex problems; FISTA gets there in far fewer iterations.

    Args:
      x:    [B, N, K] design matrices.
      y:    [B, N]    labels.
      mask: [B, N]    1.0 for active rows, 0.0 for rows excluded from the
                      fit (used to express leave-one-out CV folds as a batch).
      iters: iterations.

    Returns:
      theta: [B, K] non-negative coefficients.
    """
    w = mask[..., None]                      # [B, N, 1]
    xw = x * w
    g = jnp.einsum("bnk,bnl->bkl", xw, x)    # [B, K, K] Gram
    b = jnp.einsum("bnk,bn->bk", xw, y)      # [B, K]
    # Lipschitz bound per problem: row-sum norm of the Gram matrix.
    lip = jnp.max(jnp.sum(jnp.abs(g), axis=-1), axis=-1)  # [B]
    eta = (1.0 / jnp.maximum(lip, 1e-12))[:, None]
    theta = jnp.zeros((x.shape[0], x.shape[2]), x.dtype)  # [B, K]
    momentum = theta
    t = 1.0
    for _ in range(iters):
        grad = jnp.einsum("bkl,bl->bk", g, momentum) - b
        nxt = jnp.maximum(momentum - eta * grad, 0.0)
        t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t) ** 0.5)
        momentum = nxt + ((t - 1.0) / t_next) * (nxt - theta)
        theta, t = nxt, t_next
    return theta


def fit_residual_rmse(x, y, mask, theta):
    """RMSE of ``x @ theta`` vs ``y`` over rows where mask == 1. [B]."""
    pred = jnp.einsum("bnk,bk->bn", x, theta)
    se = mask * (pred - y) ** 2
    n = jnp.maximum(jnp.sum(mask, axis=-1), 1.0)
    return jnp.sqrt(jnp.sum(se, axis=-1) / n)


def svm_step(x, y, w, lr: float = 0.1, reg: float = 1e-3):
    """One hinge-loss (linear SVM) gradient step.

    Args:
      x: [T, D] features, y: [T] labels in {-1, +1}, w: [D] weights.
    Returns:
      (w_next [D], loss []) — loss is mean hinge + L2 term.
    """
    margin = y * (x @ w)                     # [T]
    active = (margin < 1.0).astype(x.dtype)  # subgradient indicator
    grad = -(x * (y * active)[:, None]).mean(axis=0) + reg * w
    loss = jnp.maximum(0.0, 1.0 - margin).mean() + 0.5 * reg * jnp.sum(w * w)
    return w - lr * grad, loss


def lr_step(x, y, w, lr: float = 0.1, reg: float = 1e-3):
    """One logistic-regression gradient step. y in {0, 1}."""
    z = x @ w
    p = 1.0 / (1.0 + jnp.exp(-z))
    grad = (x * (p - y)[:, None]).mean(axis=0) + reg * w
    # numerically-stable mean NLL
    nll = jnp.mean(jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
    loss = nll + 0.5 * reg * jnp.sum(w * w)
    return w - lr * grad, loss


def kmeans_step(x, c):
    """One Lloyd iteration: assign rows of x to nearest centroid, recompute.

    Args:
      x: [T, D] points, c: [K, D] centroids.
    Returns:
      (c_next [K, D], inertia []) — empty clusters keep their old centroid.
    """
    d2 = ((x[:, None, :] - c[None, :, :]) ** 2).sum(-1)   # [T, K]
    assign = jnp.argmin(d2, axis=-1)                       # [T]
    onehot = (assign[:, None] == jnp.arange(c.shape[0])[None, :]).astype(x.dtype)
    counts = onehot.sum(axis=0)                            # [K]
    sums = onehot.T @ x                                    # [K, D]
    c_next = jnp.where(counts[:, None] > 0,
                       sums / jnp.maximum(counts, 1.0)[:, None], c)
    inertia = jnp.min(d2, axis=-1).mean()
    return c_next, inertia
