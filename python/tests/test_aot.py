"""AOT path: every EXPORTS entry lowers to HLO text that XLA re-parses.

This validates the build-time half of the interchange contract; the Rust
integration tests validate the load-and-execute half against the same
artifacts.
"""

import json

import jax
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


@pytest.mark.parametrize("name", sorted(model.EXPORTS))
def test_export_lowers_to_parseable_hlo_text(name):
    fn, example_args = model.EXPORTS[name]
    lowered = jax.jit(fn).lower(*example_args)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "ROOT" in text
    # Round-trip through the HLO text parser (what the rust loader does).
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None


def test_manifest_written(tmp_path):
    import subprocess
    import sys

    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=str(__import__("pathlib").Path(__file__).parent.parent),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text" and manifest["return_tuple"]
    assert set(manifest["entries"]) == set(model.EXPORTS)
    for name, entry in manifest["entries"].items():
        assert (out / entry["file"]).exists()
        assert entry["inputs"] and entry["outputs"]


def test_exports_match_eager_numerics():
    """Lowered+compiled executables agree with eager jax on random input."""
    for name, (fn, example_args) in model.EXPORTS.items():
        r = np.random.default_rng(42)
        args = [r.normal(size=s.shape).astype(np.float32) * 0.1
                for s in example_args]
        eager = jax.tree_util.tree_leaves(fn(*args))
        compiled = jax.jit(fn).lower(*[jax.ShapeDtypeStruct(a.shape, a.dtype)
                                       for a in args]).compile()
        got = jax.tree_util.tree_leaves(compiled(*args))
        for e, g in zip(eager, got):
            np.testing.assert_allclose(g, e, rtol=1e-4, atol=1e-4,
                                       err_msg=name)
