"""Pallas kernels (interpret=True) vs pure-jnp oracles — the core signal.

Hypothesis sweeps shapes/seeds; every kernel must match ref.py to float32
tolerances, and the NNLS fit must agree with scipy's bounded curve_fit on
the paper's Eq.-1 model family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linfit, ml_steps, ref

jax.config.update("jax_enable_x64", False)

HYP = dict(max_examples=15, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------- linfit ---

@settings(**HYP)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 8),
    n=st.integers(2, 12),
    k=st.integers(1, 4),
)
def test_linfit_matches_ref(seed, b, n, k):
    r = _rng(seed)
    x = r.normal(1.0, 0.5, size=(b, n, k)).astype(np.float32)
    theta_true = r.uniform(0.0, 2.0, size=(b, k)).astype(np.float32)
    y = np.einsum("bnk,bk->bn", x, theta_true).astype(np.float32)
    y += r.normal(0, 0.01, size=y.shape).astype(np.float32)
    mask = (r.uniform(size=(b, n)) > 0.2).astype(np.float32)
    # keep at least 2 active rows per problem so the fit is sane
    mask[:, :2] = 1.0

    got_theta, got_rmse = linfit.linfit(x, y, mask)
    ref_theta = ref.nnls_fit(x, y, mask, iters=linfit.PGD_ITERS)
    ref_rmse = ref.fit_residual_rmse(x, y, mask, ref_theta)

    np.testing.assert_allclose(got_theta, ref_theta, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_rmse, ref_rmse, rtol=1e-4, atol=1e-4)
    assert np.all(np.asarray(got_theta) >= 0.0), "NNLS must be non-negative"


@pytest.mark.parametrize("seed", range(4))
def test_linfit_matches_scipy_curve_fit(seed):
    """Paper Eq. 1: D_size = th0 + th1*scale, positive bounds, vs scipy."""
    from scipy.optimize import curve_fit

    r = _rng(seed)
    scales = np.array([1.0, 2.0, 3.0], dtype=np.float32)
    th = r.uniform(0.1, 5.0, size=2).astype(np.float32)
    sizes = th[0] + th[1] * scales + r.normal(0, 1e-3, 3).astype(np.float32)

    popt, _ = curve_fit(lambda s, a, b: a + b * s, scales, sizes,
                        bounds=(0, np.inf))

    x = np.stack([np.ones_like(scales), scales], axis=-1)[None]  # [1,3,2]
    theta, _ = linfit.linfit(x, sizes[None], np.ones((1, 3), np.float32),
                             iters=3000)
    np.testing.assert_allclose(theta[0], popt, rtol=5e-3, atol=5e-3)


def test_linfit_fold_masks_give_loo_cv():
    """Masking one row out reproduces a leave-one-out fit of the others."""
    r = _rng(7)
    n = 4
    x = np.stack([np.ones(n), np.arange(1, n + 1, dtype=np.float32)],
                 axis=-1).astype(np.float32)[None]
    y = (3.0 + 2.0 * np.arange(1, n + 1)).astype(np.float32)[None]
    full_mask = np.ones((1, n), np.float32)
    loo_mask = full_mask.copy()
    loo_mask[0, 2] = 0.0

    th_loo, _ = linfit.linfit(x, y, loo_mask, iters=2000)
    # exact data -> same (3, 2) solution with or without the row
    np.testing.assert_allclose(th_loo[0], [3.0, 2.0], rtol=1e-3, atol=1e-2)


def test_linfit_aot_shapes_run():
    """The exact AOT contract shapes execute and return finite values."""
    b, n, k = linfit.BATCH, linfit.POINTS, linfit.FEATURES
    r = _rng(0)
    x = r.normal(size=(b, n, k)).astype(np.float32)
    y = r.normal(size=(b, n)).astype(np.float32)
    m = np.ones((b, n), np.float32)
    theta, rmse = linfit.linfit(x, y, m)
    assert theta.shape == (b, k) and rmse.shape == (b,)
    assert np.all(np.isfinite(theta)) and np.all(np.isfinite(rmse))


# -------------------------------------------------------------- ml steps ---

@settings(**HYP)
@given(seed=st.integers(0, 2**31 - 1), tiles=st.integers(1, 4),
       d=st.sampled_from([8, 32, 64]))
def test_svm_step_matches_ref(seed, tiles, d):
    r = _rng(seed)
    t = tiles * ml_steps.TILE_T
    x = r.normal(size=(t, d)).astype(np.float32)
    y = np.sign(r.normal(size=t)).astype(np.float32)
    w = r.normal(size=d).astype(np.float32) * 0.1

    gsum, lsum = ml_steps.svm_grad_sums(x, y, w)
    from compile import model
    w_ref, loss_ref = ref.svm_step(x, y, w, lr=model.SVM_LR, reg=model.SVM_REG)
    w_got, loss_got = model.svm_iteration(x, y, w)
    np.testing.assert_allclose(w_got, w_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(loss_got, loss_ref, rtol=2e-4, atol=2e-4)
    assert np.all(np.isfinite(gsum)) and np.isfinite(lsum[0])


@settings(**HYP)
@given(seed=st.integers(0, 2**31 - 1), tiles=st.integers(1, 4),
       d=st.sampled_from([8, 64]))
def test_logreg_step_matches_ref(seed, tiles, d):
    r = _rng(seed)
    t = tiles * ml_steps.TILE_T
    x = r.normal(size=(t, d)).astype(np.float32)
    y = (r.uniform(size=t) > 0.5).astype(np.float32)
    w = r.normal(size=d).astype(np.float32) * 0.1

    from compile import model
    w_ref, loss_ref = ref.lr_step(x, y, w, lr=model.LOGREG_LR,
                                  reg=model.LOGREG_REG)
    w_got, loss_got = model.logreg_iteration(x, y, w)
    np.testing.assert_allclose(w_got, w_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(loss_got, loss_ref, rtol=2e-4, atol=2e-4)


@settings(**HYP)
@given(seed=st.integers(0, 2**31 - 1), tiles=st.integers(1, 3),
       d=st.sampled_from([4, 16]), k=st.sampled_from([2, 8]))
def test_kmeans_step_matches_ref(seed, tiles, d, k):
    r = _rng(seed)
    t = tiles * ml_steps.TILE_T
    x = r.normal(size=(t, d)).astype(np.float32)
    c = r.normal(size=(k, d)).astype(np.float32)

    from compile import model
    c_ref, inertia_ref = ref.kmeans_step(x, c)
    c_got, inertia_got = model.kmeans_iteration(x, c)
    np.testing.assert_allclose(c_got, c_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(inertia_got, inertia_ref, rtol=1e-4, atol=1e-4)


def test_kmeans_empty_cluster_keeps_centroid():
    x = np.zeros((ml_steps.TILE_T, 4), np.float32)
    c = np.stack([np.zeros(4), np.full(4, 100.0)]).astype(np.float32)
    from compile import model
    c_next, _ = model.kmeans_iteration(x, c)
    np.testing.assert_allclose(c_next[1], c[1])  # far centroid untouched


def test_svm_converges_on_separable_data():
    """A few iterations reduce hinge loss on a linearly separable set."""
    from compile import model
    r = _rng(3)
    t, d = ml_steps.TILE_T * 2, 16
    w_true = r.normal(size=d).astype(np.float32)
    x = r.normal(size=(t, d)).astype(np.float32)
    y = np.sign(x @ w_true).astype(np.float32)
    w = np.zeros(d, np.float32)
    losses = []
    for _ in range(10):
        w, loss = model.svm_iteration(x, y, w)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8
